"""System invariants of the discord algorithms (the paper's core).

The load-bearing properties:
  1. EXACTNESS: hotsax / hst / hst_jax / matrix_profile return exactly
     the brute-force discords (position and nnd) on arbitrary series;
  2. the warm-up + topology nnd profile is a pointwise UPPER BOUND of
     the true profile (that is the exactness argument's premise);
  3. k discords never overlap (non-self-match rule);
  4. dadd is exact whenever r < nnd of the k-th discord.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; skip cleanly if absent
from hypothesis import given, settings, strategies as st

from repro.core import find_discords
from repro.core.serial.brute import exact_nnd_profile
from repro.core.sax import SaxTable
from repro.core.serial.common import CountedSeries
from repro.core.serial.hst import _HstState


def _mk_series(seed, n=600, kind="mix"):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    base = np.sin(0.07 * t) + 0.1 * rng.normal(size=n)
    if kind == "mix":
        p = int(rng.integers(100, n - 100))
        base[p:p + 40] += rng.uniform(0.5, 1.5) * np.sin(
            np.linspace(0, np.pi, 40))
    return base


EXACT_METHODS = ("hotsax", "hst", "hst_jax", "matrix_profile")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_exactness_first_discord(seed):
    x = _mk_series(seed)
    s = 32
    ref = find_discords(x, s, 1, method="brute")
    for m in EXACT_METHODS:
        r = find_discords(x, s, 1, method=m, seed=seed % 7)
        assert r.positions == ref.positions, (m, r, ref)
        assert r.nnds[0] == pytest.approx(ref.nnds[0], rel=1e-3)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_exactness_k_discords(seed):
    x = _mk_series(seed, n=500)
    s = 24
    k = 3
    ref = find_discords(x, s, k, method="brute")
    for m in ("hotsax", "hst", "hst_jax"):
        r = find_discords(x, s, k, method=m, seed=seed % 5)
        assert r.positions == ref.positions, (m, seed)
    # non-overlap
    for i in range(k):
        for j in range(i + 1, k):
            assert abs(ref.positions[i] - ref.positions[j]) >= s


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_warmup_profile_is_upper_bound(seed):
    x = _mk_series(seed, n=400)
    s = 20
    rng = np.random.default_rng(seed)
    ctx = CountedSeries(x, s)
    table = SaxTable(x, s, 4, 4)
    stt = _HstState(ctx, table, rng)
    stt.warm_up()
    stt.short_range_time_topology()
    true_prof = exact_nnd_profile(x, s)
    # approximate nnd may only over-estimate, never under-estimate
    assert np.all(stt.nnd >= true_prof - 1e-6)
    # the neighbor stored must realize the stored distance
    for i in range(0, ctx.n, 37):
        g = int(stt.ngh[i])
        if g >= 0:
            assert ctx.d_block_raw(i, np.array([g]))[0] == \
                pytest.approx(stt.nnd[i], abs=1e-6)


def test_dadd_exact_below_r(anomalous_series):
    x, _ = anomalous_series
    s = 64
    ref = find_discords(x, s, 2, method="brute")
    r = find_discords(x, s, 2, method="dadd", r=0.9 * ref.nnds[-1])
    assert r.positions == ref.positions
    # r too large -> flagged, not silently wrong
    r2 = find_discords(x, s, 2, method="dadd", r=1.5 * ref.nnds[0])
    assert r2.extra["r_too_large"] or r2.positions == ref.positions


def test_call_counts_sane(anomalous_series):
    """HST must beat HOT SAX and both must beat brute force."""
    x, _ = anomalous_series
    s = 64
    b = find_discords(x, s, 1, method="brute")
    hs = find_discords(x, s, 1, method="hotsax")
    h = find_discords(x, s, 1, method="hst")
    assert h.calls < hs.calls < b.calls
    assert h.cps < 60            # HST cps is small on benign series


def test_implanted_anomaly_found(ecg_series):
    x, pos = ecg_series
    s = 120
    r = find_discords(x, s, len(pos), method="hst")
    for p in pos:
        assert any(abs(q - p) < 2 * s for q in r.positions), (pos, r)

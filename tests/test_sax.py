"""SAX layer: breakpoints, PAA, cluster-table invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; skip cleanly if absent
from hypothesis import given, settings, strategies as st

from repro.core.sax import SaxTable, gaussian_breakpoints, paa, sax_words


def test_breakpoints_monotone_and_sized():
    for a in (2, 3, 4, 8, 16):
        bp = gaussian_breakpoints(a)
        assert bp.shape == (a - 1,)
        assert np.all(np.diff(bp) > 0)
    assert gaussian_breakpoints(4)[1] == pytest.approx(0.0, abs=1e-12)


def test_paa_requires_divisibility():
    x = np.random.default_rng(0).normal(size=200)
    with pytest.raises(ValueError):
        paa(x, 10, 3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), s=st.sampled_from([8, 12, 16]),
       P=st.sampled_from([2, 4]), alpha=st.sampled_from([3, 4, 6]))
def test_sax_table_partitions(seed, s, P, alpha):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=300)
    table = SaxTable(x, s, P, alpha)
    n = x.shape[0] - s + 1
    # clusters partition [0, n)
    members = np.concatenate([m for m in table.clusters.values()])
    assert sorted(members.tolist()) == list(range(n))
    # per-sequence size bookkeeping agrees
    for w, m in table.clusters.items():
        assert np.all(table.cluster_size[m] == m.size)
    # size ordering smallest -> largest
    sizes = [table.clusters[k].size for k in table.keys_by_size]
    assert sizes == sorted(sizes)


def test_paa_znormalized_windows():
    """PAA of a z-normalized window must average to ~0."""
    x = np.random.default_rng(3).normal(size=500)
    pa = paa(x, 16, 4)
    assert np.allclose(pa.mean(axis=1), 0.0, atol=1e-6)


def test_words_in_range():
    x = np.random.default_rng(4).normal(size=400)
    w = sax_words(x, 12, 4, 4)
    assert w.min() >= 0 and w.max() < 4 ** 4

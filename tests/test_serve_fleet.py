"""Multi-tenant streaming serve plane (repro.serve.DiscordServer).

  1. PARITY — micro-batched coalesced appends are **bit-identical**
     (profiles and neighbor ids, every rung) to per-tenant sequential
     appends, on mixed fleets of single-window and pan tenants.
  2. SHARED CACHE — tenants with bucket-identical specs share one
     engine and one plan cache; LRU eviction respects the budget and
     moves the eviction counters without breaking parity.
  3. ADMISSION — the pending queue is bounded; over-budget appends
     raise AdmissionError loudly and the rejection is counted.
  4. COMPILE-ONCE, FLEET-WIDE — steady-state flushes add zero jit
     traces, and aggregate traces == aggregate plan builds.
  5. TELEMETRY — ServeStats counters (dispatch ratio, hit rate,
     straggler snapshot) are consistent; the DiscordMonitor rides a
     shared server with the same reports it produced privately.
  6. PROPERTY (seeded) — randomized fleets (mixed specs/ladders/znorm
     modes, append sizes and order, tight budgets forcing mid-flight
     evictions) keep the bit-identical parity contract on every
     backend.  ``test_serve_property.py`` re-drives the same case
     runner under hypothesis when it is installed.
  7. SOAK (``-m slow``) — 1k tenants x 100 appends under a tight
     cache budget: bounded cache, moving eviction counters, zero new
     traces after warm-up, parity spot-checks.
"""
import numpy as np
import pytest

from repro.core import DiscordEngine, PanStream, SearchSpec
from repro.serve import AdmissionError, DiscordServer

BACKENDS = ("numpy", "xla", "pallas")


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------
def _series(rng, n):
    x = np.sin(0.07 * np.arange(n)) + 0.15 * rng.normal(size=n)
    if n > 120:
        x[n // 2:n // 2 + 40] += 0.9
    return x


def _rungs(st):
    return range(len(st.ladder)) if isinstance(st, PanStream) else (0,)


def assert_stream_equal(st, ref, label=""):
    """Bit-identical: d2 profile AND neighbor ids, every rung."""
    assert type(st) is type(ref)
    for r in _rungs(st):
        if isinstance(st, PanStream):
            p, q = st.profile(r), ref.profile(r)
            n, m = st.neighbors(r), ref.neighbors(r)
        else:
            p, q = st.profile(), ref.profile()
            n, m = st.neighbors(), ref.neighbors()
        assert np.array_equal(p, q), f"{label}: profile rung {r}"
        assert np.array_equal(n, m), f"{label}: neighbors rung {r}"


def run_fleet_case(seed, backend, n_tenants=None):
    """One randomized fleet served two ways — coalesced through a
    DiscordServer vs per-tenant sequential streams — then compared
    bit-identically.  Shared by the seeded property test here and the
    hypothesis suite in test_serve_property.py."""
    rng = np.random.default_rng(seed)
    n_tenants = int(n_tenants or rng.integers(2, 6))
    pool = [32, 64, (32, 48), (16, 32, 48)]
    specs, histories, rounds = [], [], []
    n_rounds = int(rng.integers(1, 4))
    for t in range(n_tenants):
        s = pool[int(rng.integers(len(pool)))]
        specs.append(SearchSpec(s=s, k=2, method="matrix_profile",
                                znorm=bool(rng.integers(2)),
                                backend=backend))
        histories.append(_series(rng, int(rng.integers(20, 400))))
    for _ in range(n_rounds):
        rounds.append([_series(rng, int(rng.integers(1, 120)))
                       for _ in range(n_tenants)])
    # a tight budget on some draws forces evictions mid-flight
    budget = int(rng.integers(1, 4)) if rng.integers(2) else None

    srv = DiscordServer(cache_budget=budget,
                        max_group=int(rng.integers(2, 9)))
    for t in range(n_tenants):
        srv.open(t, specs[t], history=histories[t])
    flush_every_round = bool(rng.integers(2))
    for rnd in rounds:
        for t in range(n_tenants):
            srv.append(t, rnd[t])
        if flush_every_round:
            srv.flush()
    srv.flush()

    for t in range(n_tenants):
        ref = DiscordEngine(specs[t]).open_stream(
            history=histories[t])
        for rnd in rounds:
            ref.append(rnd[t])
        assert_stream_equal(srv.stream(t), ref,
                            f"seed={seed} tenant={t} "
                            f"spec={specs[t]}")
    st = srv.stats()
    assert st.pending == 0
    assert st.appends_applied == st.appends_queued
    assert st.traces == st.plans, "fleet-wide compile-once broke"
    if budget is not None:
        assert len(srv.plan_cache) <= budget
    return srv


# ----------------------------------------------------------------------
# 1. parity + coalescing on a deterministic mixed fleet
# ----------------------------------------------------------------------
def test_mixed_fleet_parity_and_coalescing():
    rng = np.random.default_rng(0)
    specs = [SearchSpec(s=64, k=2, method="matrix_profile",
                        backend="xla"),
             SearchSpec(s=(32, 48), k=2, method="matrix_profile",
                        backend="xla")]
    hist = [_series(rng, 300) for _ in range(8)]
    apps = [[_series(rng, 40) for _ in range(8)] for _ in range(4)]

    srv = DiscordServer()
    for t in range(8):
        srv.open(t, specs[t % 2], history=hist[t])
    for rnd in apps:
        for t in range(8):
            srv.append(t, rnd[t])
        srv.flush()

    for t in range(8):
        ref = DiscordEngine(specs[t % 2]).open_stream(history=hist[t])
        for rnd in apps:
            ref.append(rnd[t])
        assert_stream_equal(srv.stream(t), ref, f"tenant {t}")
        # discord queries ride the same folded state
        got, want = srv.discords(t), ref.discords()
        if t % 2:      # pan tenant: per-rung results
            assert [r.positions for r in got.per_rung] == \
                [r.positions for r in want.per_rung]
        else:
            assert got.positions == want.positions

    st = srv.stats()
    assert st.tenants == 8 and st.engines == 2
    # 8 tenants x 3 rounds sequential, but 4-lane coalescing per spec:
    # the dispatch ratio is the micro-batching win
    assert st.coalesced > 0
    assert st.dispatches < st.sequential_dispatches
    assert st.dispatch_ratio < 0.5
    assert st.cache_hit_rate > 0.5, \
        "bucket-identical tenants must share compilations"


def test_queued_appends_apply_in_arrival_order():
    """server.append(t, p1); append(t, p2); flush() must equal
    stream.append(p1).append(p2) — the flush-rounds contract."""
    rng = np.random.default_rng(1)
    spec = SearchSpec(s=32, k=2, method="matrix_profile",
                      backend="numpy")
    h, p1, p2, p3 = (_series(rng, n) for n in (200, 30, 45, 7))
    srv = DiscordServer()
    srv.open("a", spec, history=h)
    srv.append("a", p1)
    srv.append("a", p2)
    srv.append("a", p3)
    assert srv.stats().pending == 4    # history queues like an append
    rounds = srv.flush()
    assert rounds == 4, "one pending append per tenant per round"
    ref = DiscordEngine(spec).open_stream(history=h)
    ref.append(p1).append(p2).append(p3)
    assert_stream_equal(srv.stream("a"), ref)


# ----------------------------------------------------------------------
# 2. shared plan cache + eviction
# ----------------------------------------------------------------------
def test_engines_dedupe_and_share_one_cache():
    spec = SearchSpec(s=64, k=2, method="matrix_profile",
                      backend="numpy")
    other = SearchSpec(s=32, k=2, method="matrix_profile",
                       backend="numpy")
    srv = DiscordServer()
    srv.open("a", spec)
    srv.open("b", spec)
    srv.open("c", other)
    ea, eb = (srv._tenants[t].stream.engine for t in "ab")
    ec = srv._tenants["c"].stream.engine
    assert ea is eb, "bucket-identical specs must share the engine"
    assert ec is not ea
    assert ea.plan_cache is ec.plan_cache is srv.plan_cache
    assert srv.stats().engines == 2


def test_cache_eviction_under_budget_keeps_parity():
    rng = np.random.default_rng(2)
    specs = [SearchSpec(s=s, k=2, method="matrix_profile",
                        backend="numpy") for s in (16, 32, 64)]
    hist = [_series(rng, 260) for _ in specs]
    app = [_series(rng, 50) for _ in specs]

    srv = DiscordServer(cache_budget=1)
    for t, spec in enumerate(specs):
        srv.open(t, spec, history=hist[t])
    for t in range(len(specs)):
        srv.append(t, app[t])
    srv.flush()

    cache = srv.plan_cache.as_dict()
    assert len(srv.plan_cache) <= 1, "budget must bound live plans"
    assert cache["evictions"] > 0, "three geometries through a " \
                                   "1-plan budget must evict"
    for t, spec in enumerate(specs):
        ref = DiscordEngine(spec).open_stream(history=hist[t])
        ref.append(app[t])
        assert_stream_equal(srv.stream(t), ref, f"tenant {t}")


def test_compile_once_fleet_wide_steady_state():
    """Once every (geometry, lane-count) plan is warm, further flush
    rounds add zero jit traces."""
    rng = np.random.default_rng(3)
    spec = SearchSpec(s=32, k=2, method="matrix_profile",
                      backend="xla")
    srv = DiscordServer()
    for t in range(4):
        # 150 + 5 appends x 16 = 230 stays inside the 256 bucket, so
        # steady state really is one (geometry, B) plan key
        srv.open(t, spec, history=_series(rng, 150))
    for _ in range(2):                      # warm-up: fill + tail
        for t in range(4):
            srv.append(t, _series(rng, 16))
        srv.flush()
    warm = srv.stats().traces
    for _ in range(3):                      # steady state, same bucket
        for t in range(4):
            srv.append(t, _series(rng, 16))
        srv.flush()
    st = srv.stats()
    assert st.traces == warm, "steady-state flushes must not retrace"
    assert st.traces == st.plans


# ----------------------------------------------------------------------
# 3. admission control + tenancy lifecycle
# ----------------------------------------------------------------------
def test_admission_bounded_queue_rejects_loudly():
    rng = np.random.default_rng(4)
    srv = DiscordServer(max_pending=3)
    srv.open("a", s=32, k=2, method="matrix_profile", backend="numpy")
    for _ in range(3):
        srv.append("a", _series(rng, 40))
    with pytest.raises(AdmissionError, match="max_pending"):
        srv.append("a", _series(rng, 40))
    assert srv.stats().rejected == 1
    assert srv.stats().pending == 3, "rejected append must not queue"
    srv.flush()                             # draining re-admits
    srv.append("a", _series(rng, 40))
    srv.flush()
    assert srv.stats().pending == 0


def test_tenancy_lifecycle_and_argument_errors():
    rng = np.random.default_rng(5)
    spec = SearchSpec(s=32, k=2, method="matrix_profile",
                      backend="numpy")
    srv = DiscordServer()
    srv.open("a", spec, history=_series(rng, 150))
    with pytest.raises(ValueError, match="already open"):
        srv.open("a", spec)
    with pytest.raises(TypeError, match="not both"):
        srv.open("b", spec, s=64)
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.append("ghost", np.zeros(8))
    # empty appends are no-ops, not queue slots
    srv.flush()                             # drain the queued history
    srv.append("a", [])
    assert srv.stats().pending == 0

    srv.append("a", _series(rng, 30))
    stream = srv.close("a")                 # applies pending first
    assert stream.n_points == 180
    assert "a" not in srv and len(srv) == 0
    with pytest.raises(KeyError):
        srv.stream("a")


def test_sharded_specs_are_rejected_with_pointer():
    srv = DiscordServer()
    with pytest.raises(ValueError, match="non-sharded"):
        srv.open("a", SearchSpec(s=64, k=2, method="matrix_profile",
                                 backend="xla", ndev=2))


def test_profile_rung_validation():
    rng = np.random.default_rng(6)
    srv = DiscordServer()
    srv.open("flat", s=32, k=2, method="matrix_profile",
             backend="numpy", history=_series(rng, 200))
    srv.open("pan", s=(16, 32), k=2, method="matrix_profile",
             backend="numpy", history=_series(rng, 200))
    assert srv.profile("flat").size > 0
    assert srv.profile("pan", rung=1).size > 0
    with pytest.raises(ValueError, match="rung"):
        srv.profile("flat", rung=1)


# ----------------------------------------------------------------------
# 4. telemetry: stats shape, straggler wiring, monitor rides the fleet
# ----------------------------------------------------------------------
def test_stats_report_shape_and_repr():
    srv = DiscordServer(cache_budget=8)
    rep = srv.report()
    for key in ("tenants", "engines", "dispatches",
                "sequential_dispatches", "dispatch_ratio", "cache",
                "pending", "rejected", "straggler"):
        assert key in rep
    assert rep["cache"]["budget"] == 8
    assert "DiscordServer(" in repr(srv)
    assert srv.stats().dispatch_ratio == 0.0    # no dispatches yet


def test_straggler_detector_observes_plan_groups():
    rng = np.random.default_rng(7)
    srv = DiscordServer(straggler_slots=2)
    for t in range(4):
        srv.open(t, s=32, k=2, method="matrix_profile",
                 backend="numpy", history=_series(rng, 200))
    srv.flush()
    snap = srv.stats().straggler
    assert snap is not None
    assert set(snap) == {"suspects", "evict", "cross_sectional",
                         "temporal"}


def test_monitor_rides_shared_server():
    from repro.telemetry.buffer import MetricBuffer
    from repro.telemetry.monitor import DiscordMonitor

    rng = np.random.default_rng(8)
    x = 0.1 * rng.normal(size=400)
    x[250:270] += 3.0

    def fill(buf):
        for i, v in enumerate(x):
            buf.log(i, {"loss": float(v), "grad": float(v) * 0.5})

    srv = DiscordServer()
    buf1 = MetricBuffer()
    fill(buf1)
    shared = DiscordMonitor(buf1, window=32, min_points=64,
                            server=srv)
    got = shared.scan()

    buf2 = MetricBuffer()
    fill(buf2)
    private = DiscordMonitor(buf2, window=32, min_points=64)
    want = private.scan()

    assert set(got) == set(want) == {"loss", "grad"}
    for name in got:
        assert got[name].positions == want[name].positions
        assert got[name].flagged == want[name].flagged
    # the metrics really are tenants of the caller's server
    assert len(srv) == 2
    assert all(t.startswith("metric::") for t in srv.tenant_ids)
    assert srv.stats().coalesced > 0, \
        "same-geometry metrics must micro-batch in one scan flush"


# ----------------------------------------------------------------------
# 6. seeded property suite (hypothesis re-drives run_fleet_case when
#    installed — see test_serve_property.py)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [11, 12, 13])
def test_property_random_fleet_parity(backend, seed):
    run_fleet_case(seed, backend)


# ----------------------------------------------------------------------
# 7. soak (slow; own CI job): 1k tenants x 100 appends, tight budget
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_soak_1k_tenants_bounded_cache_and_no_retrace():
    rng = np.random.default_rng(9)
    spec = SearchSpec(s=16, k=2, method="matrix_profile",
                      backend="xla")
    n_tenants, n_appends, app = 1000, 100, 8
    srv = DiscordServer(cache_budget=3, max_group=64)
    hist = [_series(rng, 64) for _ in range(n_tenants)]
    apps = rng.normal(size=(n_appends, n_tenants, app))
    for t in range(n_tenants):
        srv.open(t, spec, history=hist[t])
    traces_at = {}
    for i in range(n_appends):
        for t in range(n_tenants):
            srv.append(t, apps[i, t])
        srv.flush()
        if i in (n_appends - 21, n_appends - 1):
            traces_at[i] = srv.stats().traces

    st = srv.stats()
    # bounded compile memory: the live cache respects the budget and
    # the eviction counters moved while the series crossed buckets
    assert len(srv.plan_cache) <= 3
    assert st.cache["evictions"] > 0
    # zero new jit traces after warm-up (last 20 rounds are steady)
    assert traces_at[n_appends - 1] == traces_at[n_appends - 21], \
        "steady-state soak rounds must not retrace"
    assert st.traces == st.plans
    assert st.pending == 0
    assert st.appends_applied == st.appends_queued == \
        n_tenants * (n_appends + 1)
    assert st.dispatch_ratio < 0.5
    assert st.cache_hit_rate > 0.9
    # parity spot-checks against sequential sessions
    for t in (0, 499, 999):
        ref = DiscordEngine(spec).open_stream(history=hist[t])
        for i in range(n_appends):
            ref.append(apps[i, t])
        assert_stream_equal(srv.stream(t), ref, f"soak tenant {t}")

"""Session API contract: SearchSpec + DiscordEngine + DiscordStream.

  1. SPEC — frozen, validated, hashable; aliases canonicalize
     (``distributed`` == ``ring``, ``jnp`` == ``xla``); multi-window
     tuples only with the profile method.
  2. COMPILE-ONCE — a second search in the same length bucket triggers
     zero new jit traces (the engine's plan bodies count their own
     traces); a new bucket traces exactly once more; streams share the
     session's plan cache.
  3. STREAMING — ``DiscordStream.append``-built profiles match a
     from-scratch search of the concatenated series on every backend
     (numpy / xla / pallas-interpret), in both z-normalized and raw
     Euclidean mode, while sweeping only the appended tail tile rows
     (tile-lane counter strictly below the full-sweep count).
  4. REPORTING — batched results carry the true per-batch wall clock
     and total tile-op counts; the deprecated wrappers warn and agree
     with the session API.
"""
import numpy as np
import pytest

from repro.core import (DiscordEngine, DiscordStream, SearchSpec,
                        find_discords, find_discords_batched)
from repro.core.serial.brute import exact_nnd_profile
from repro.core.spec import canonical_method, length_bucket
from repro.core.tiles import topk_nonoverlapping

BACKENDS = ("numpy", "xla", "pallas")


def _series(seed, n=420):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    x = np.sin(0.07 * t) + 0.1 * rng.normal(size=n)
    if n > 200:               # short chunks (stream appends) stay plain
        p = int(rng.integers(80, n - 80))
        x[p:p + 30] += rng.uniform(0.7, 1.3) * np.sin(
            np.linspace(0, np.pi, 30))
    return x


# ----------------------------------------------------------------------
# SearchSpec
# ----------------------------------------------------------------------
def test_spec_canonicalization_and_aliases():
    assert canonical_method("distributed") == "ring"
    assert canonical_method("ring") == "ring"
    assert canonical_method("scamp") == "matrix_profile"
    assert SearchSpec(s=32, method="distributed").method == "ring"
    assert SearchSpec(s=32, backend="jnp").backend == "xla"
    assert SearchSpec(s=[48]).s == 48              # singleton -> scalar
    assert SearchSpec(s=[48, 64], method="mp").s == (48, 64)


@pytest.mark.parametrize("bad", [
    dict(s=32, method="nope"),
    dict(s=1),
    dict(s=32, k=0),
    dict(s=32, r=-1.0),
    dict(s=32, backend="cuda-typo"),
    dict(s=(32, 48), method="hst"),        # multi-window needs profile
    dict(s=(32, 32), method="matrix_profile"),     # duplicate lengths
    dict(s=32, method="hst_jax", znorm=False),     # Eq.(3)-only method
    dict(s=32, method="dadd", znorm=False),
    dict(s=32, method="hst", ndev=2),      # ndev is sharded-plane only
    dict(s=32, method="ring", ndev=0),
])
def test_spec_validation_rejects(bad):
    with pytest.raises(ValueError):
        SearchSpec(**bad)


def test_spec_hashable_and_replace():
    a = SearchSpec(s=64, k=2, method="matrix_profile")
    b = SearchSpec(s=64, k=2, method="scamp")      # alias -> equal spec
    assert a == b and hash(a) == hash(b)
    cache = {a: "plan"}
    assert cache[b] == "plan"
    c = a.replace(k=3)
    assert c.k == 3 and c != a and a.k == 2        # frozen original


def test_length_bucket_powers_of_two():
    assert length_bucket(1) == 256
    assert length_bucket(256) == 256
    assert length_bucket(257) == 512
    assert length_bucket(40, lo=32) == 64


# ----------------------------------------------------------------------
# compile-once plan cache
# ----------------------------------------------------------------------
def test_second_same_bucket_search_traces_nothing():
    eng = DiscordEngine(SearchSpec(s=32, k=2, method="matrix_profile",
                                   backend="xla"))
    r1 = eng.search(_series(0, 500))
    assert eng.stats.traces == 1 and eng.stats.plans == 1
    r2 = eng.search(_series(1, 460))       # different length, same 512
    assert eng.stats.traces == 1, "same-bucket search must not retrace"
    assert eng.stats.searches == 2
    assert r1.extra["bucket"] == r2.extra["bucket"] == 512
    eng.search(_series(2, 600))            # new 1024 bucket
    assert eng.stats.traces == 2 and eng.stats.plans == 2


def test_stream_shares_session_plan_cache():
    eng = DiscordEngine(SearchSpec(s=32, k=1, method="matrix_profile",
                                   backend="xla"))
    eng.search(_series(3, 500))
    t = eng.stats.traces
    st = eng.open_stream(history=_series(4, 430))  # same bucket: reuse
    assert eng.stats.traces == t
    st.append(_series(5, 30))              # first tail plan traces once
    assert eng.stats.traces == t + 1
    st.append(_series(6, 25))              # same (Lb, Qb): no retrace
    assert eng.stats.traces == t + 1


def test_bucketed_search_matches_exact_profile():
    x = _series(7, 500)
    for s in (24, 33):                     # tail straddles the bucket
        r = DiscordEngine(SearchSpec(s=s, k=2,
                                     method="matrix_profile",
                                     backend="xla")).search(x)
        prof = exact_nnd_profile(np.asarray(x, np.float64), s)
        pos, vals = topk_nonoverlapping(prof, 2, s)
        assert r.positions == pos
        assert np.allclose(r.nnds, vals, atol=3e-3)


# ----------------------------------------------------------------------
# streaming: parity + tail-only sweeps
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_append_parity_every_backend(backend):
    """append-built profile == from-scratch profile of the
    concatenation, and the discords agree with a full search."""
    x = _series(10, 400)
    s = 24
    eng = DiscordEngine(SearchSpec(s=s, k=2, method="matrix_profile",
                                   backend=backend))
    st = eng.open_stream(history=x[:300])
    for lo, hi in ((300, 340), (340, 371), (371, 400)):
        st.append(x[lo:hi])
    assert st.n_points == 400 and st.n_windows == 400 - s + 1
    ref = exact_nnd_profile(np.asarray(x, np.float64), s)
    assert np.allclose(st.profile(), ref, atol=3e-3), backend
    full = eng.search(x)
    got = st.discords()
    assert got.positions == full.positions, backend
    assert np.allclose(got.nnds, full.nnds, rtol=1e-4), backend
    # neighbors respect the exclusion zone
    ngh = st.neighbors()
    assert np.all(np.abs(ngh - np.arange(st.n_windows)) >= s)


def test_stream_sweeps_only_tail_rows():
    eng = DiscordEngine(SearchSpec(s=24, k=1, method="matrix_profile",
                                   backend="xla"))
    st = eng.open_stream(history=_series(11, 400))
    full_lanes = st.tile_lanes             # init == one full sweep
    before = eng.stats.tile_lanes
    st.append(_series(12, 40))
    append_lanes = eng.stats.tile_lanes - before
    assert 0 < append_lanes < full_lanes, \
        (append_lanes, full_lanes)         # tail rows only, not O(N^2)
    # a fresh from-scratch search re-sweeps the full tile grid
    eng2 = DiscordEngine(SearchSpec(s=24, k=1, method="matrix_profile",
                                    backend="xla"))
    eng2.search(np.concatenate([_series(11, 400), _series(12, 40)]))
    assert append_lanes < eng2.stats.tile_lanes


@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_raw_euclidean_parity(backend):
    """znorm=False (DADD/telemetry convention): the rank-1 norm
    correction recovers exact raw distances through the Eq. (3)
    backends."""
    x = _series(13, 380)
    s = 20
    eng = DiscordEngine(SearchSpec(s=s, k=2, method="matrix_profile",
                                   backend=backend, znorm=False))
    st = eng.open_stream(history=x[:300])
    st.append(x[300:])
    ref = exact_nnd_profile(np.asarray(x, np.float64), s, znorm=False)
    assert np.allclose(st.profile(), ref, atol=1e-2), backend


def test_stream_buffers_until_one_window():
    eng = DiscordEngine(SearchSpec(s=32, k=1, method="matrix_profile",
                                   backend="xla"))
    st = eng.open_stream()
    st.append(np.zeros(10))                # < s: no windows yet
    assert st.n_windows == 0 and st.discords().positions == []
    x = _series(14, 300)
    st2 = eng.open_stream(history=x[:20])
    st2.append(x[20:])                     # first real fill
    ref = exact_nnd_profile(np.asarray(x, np.float64), 32)
    assert np.allclose(st2.profile(), ref, atol=3e-3)


# ----------------------------------------------------------------------
# multi-window
# ----------------------------------------------------------------------
def test_multi_window_matches_single_window_searches():
    x = _series(15, 450)
    eng = DiscordEngine(SearchSpec(s=(24, 32), k=2,
                                   method="matrix_profile",
                                   backend="xla"))
    r24, r32 = eng.search(x)
    assert (r24.s, r32.s) == (24, 32)
    for r in (r24, r32):
        one = DiscordEngine(SearchSpec(s=r.s, k=2,
                                       method="matrix_profile",
                                       backend="xla")).search(x)
        assert r.positions == one.positions
        assert np.allclose(r.nnds, one.nnds, rtol=1e-4)
    # both lengths ride ONE pan-length ladder sweep (PR 4): one plan,
    # and fewer swept lanes than two independent per-length sweeps
    assert eng.stats.plans == 1
    assert eng.stats.tile_lanes < 2 * 512 ** 2


# ----------------------------------------------------------------------
# batched reporting
# ----------------------------------------------------------------------
def test_batched_true_wall_clock_and_tile_ops():
    xb = np.stack([_series(20), _series(21), _series(22)])
    eng = DiscordEngine(SearchSpec(s=32, k=2, method="matrix_profile",
                                   backend="xla"))
    rs = eng.search_batched(xb)
    assert len(rs) == 3
    # every member reports the SAME true batch wall clock, not /B
    assert len({r.runtime_s for r in rs}) == 1
    for r in rs:
        assert r.extra["batch_size"] == 3
        assert r.extra["per_series_s"] == pytest.approx(
            r.runtime_s / 3)
        assert r.extra["tile_lanes"] == 3 * 512 ** 2
    # parity with per-series searches
    for i, r in enumerate(rs):
        one = eng.search(xb[i])
        assert r.positions == one.positions
        assert np.allclose(r.nnds, one.nnds, rtol=1e-4)


# ----------------------------------------------------------------------
# deprecated wrappers
# ----------------------------------------------------------------------
def test_wrappers_warn_and_agree_with_session_api():
    x = _series(23, 400)
    with pytest.warns(DeprecationWarning):
        r = find_discords(x, 32, 2, method="matrix_profile",
                          backend="xla")
    eng = DiscordEngine(SearchSpec(s=32, k=2, method="matrix_profile",
                                   backend="xla"))
    assert r.positions == eng.search(x).positions
    with pytest.warns(DeprecationWarning):
        rb = find_discords_batched(x[None, :], 32, 2, backend="xla")
    assert rb[0].positions == r.positions
    assert "per_series_s" in rb[0].extra


def test_wrapper_accepts_both_ring_spellings():
    from repro.core.api import engine_for
    a = engine_for(SearchSpec(s=64, method="ring"))
    b = engine_for(SearchSpec(s=64, method="distributed"))
    assert a is b                          # one canonical engine


def test_wrapper_cache_respects_env_backend_flip(monkeypatch):
    """A backend=None spec re-resolves per call: flipping
    REPRO_TILE_BACKEND mid-process must not hit a stale engine."""
    from repro.core.api import engine_for
    spec = SearchSpec(s=48, method="matrix_profile")
    monkeypatch.delenv("REPRO_TILE_BACKEND", raising=False)
    default = engine_for(spec).backend
    monkeypatch.setenv("REPRO_TILE_BACKEND", "numpy")
    assert engine_for(spec).backend == "numpy"
    monkeypatch.delenv("REPRO_TILE_BACKEND")
    assert engine_for(spec).backend == default


def test_spec_coerces_numeric_fields():
    spec = SearchSpec(s=np.int64(32), k=2.0, seed=np.int32(5),
                      r=np.float32(1.5), method="dadd")
    assert spec == SearchSpec(s=32, k=2, seed=5, r=1.5, method="dadd")
    assert type(spec.k) is int and type(spec.r) is float


def test_profile_search_rejects_stray_kwargs():
    eng = DiscordEngine(SearchSpec(s=32, method="matrix_profile",
                                   backend="xla"))
    with pytest.raises(TypeError):
        eng.search(_series(30, 300), interpret=True)


def test_batched_and_stream_reject_non_profile_methods():
    """search_batched/open_stream run the exact-profile plan family;
    any other method must raise instead of silently ignoring its
    semantics (e.g. drag's threshold, hst's counted plane)."""
    for method in ("hst", "hst_jax", "drag"):
        eng = DiscordEngine(SearchSpec(s=32, method=method,
                                       backend="xla"))
        with pytest.raises(ValueError, match="profile plan"):
            eng.search_batched(np.zeros((2, 300)))
        with pytest.raises(ValueError, match="profile plan"):
            eng.open_stream()


# ----------------------------------------------------------------------
# telemetry monitor rides the stream
# ----------------------------------------------------------------------
def test_monitor_appends_instead_of_recomputing():
    from repro.telemetry import DiscordMonitor, MetricBuffer
    rng = np.random.default_rng(0)
    buf = MetricBuffer()
    mon = DiscordMonitor(buf, window=16, k=2)
    for i in range(400):
        buf.log(i, {"loss": 2.0 + 0.01 * rng.normal()})
    rep1 = mon.scan_metric("loss")
    assert rep1 is not None and not rep1.any_flagged
    assert mon.engine.stats.appends == 1   # first scan = one full fill
    for i in range(400, 500):
        v = 2.0 + 0.01 * rng.normal() + (1.5 if 450 <= i < 466 else 0.0)
        buf.log(i, {"loss": v})
    before = mon.engine.stats.tile_lanes
    rep2 = mon.scan_metric("loss")
    delta = mon.engine.stats.tile_lanes - before
    assert mon.engine.stats.appends == 2   # incremental, not recompute
    assert delta < before                  # tail sweep only
    assert rep2.any_flagged
    assert any(440 <= p <= 470 for p in rep2.flagged), rep2.flagged


def test_monitor_handles_drifting_metric():
    """The frozen-at-seed standardization keeps the f32 raw-distance
    math conditioned when the metric drifts (diffs with a large common
    offset would otherwise cancel catastrophically)."""
    from repro.telemetry import DiscordMonitor, MetricBuffer
    rng = np.random.default_rng(3)
    quiet = MetricBuffer()
    spiky = MetricBuffer()
    for i in range(600):
        base = 100.0 - 0.05 * i + 1e-4 * rng.normal()   # steep drift
        quiet.log(i, {"loss": base})
        spiky.log(i, {"loss": base + (0.5 if 400 <= i < 416 else 0.0)})
    rq = DiscordMonitor(quiet, window=16, k=2, z=6.0) \
        .scan_metric("loss")
    assert rq is not None and not rq.any_flagged, rq.flagged
    rs = DiscordMonitor(spiky, window=16, k=2).scan_metric("loss")
    assert rs.any_flagged
    assert any(380 <= p <= 430 for p in rs.flagged), rs.flagged


def test_monitor_wrapped_buffer_rebuild_is_capped():
    """Post-wrap the series is no longer append-only: the monitor
    rebuilds per scan over a bounded window, positions reported in
    visible-series index space."""
    from repro.telemetry import DiscordMonitor, MetricBuffer
    rng = np.random.default_rng(4)
    buf = MetricBuffer(capacity=512)
    mon = DiscordMonitor(buf, window=16, k=2, min_points=64,
                         max_scan_points=256)
    for i in range(700):                   # wraps at 512
        v = 2.0 + 0.01 * rng.normal() + (1.5 if 660 <= i < 676 else 0.0)
        buf.log(i, {"loss": v})
    rep = mon.scan_metric("loss")
    # no stream persisted, rebuild capped at max_scan_points
    assert "loss" not in mon._streams
    assert mon.engine.stats.tile_lanes <= 256 ** 2
    # visible series = last 512 points; spike at visible 472..487
    assert rep.any_flagged
    assert any(450 <= p <= 500 for p in rep.flagged), rep.flagged
    lanes = mon.engine.stats.tile_lanes
    rep2 = mon.scan_metric("loss")         # no new points: memo hit,
    assert rep2.flagged == rep.flagged     # no O(n^2) re-sweep
    assert mon.engine.stats.tile_lanes == lanes
    buf.log(700, {"loss": 2.0})            # new point invalidates memo
    rep3 = mon.scan_metric("loss")
    assert mon.engine.stats.tile_lanes > lanes
    assert rep3.any_flagged

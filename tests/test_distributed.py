"""Multi-device discord search (shard_map) — runs on 8 simulated
devices in a subprocess (device count must be set before jax init)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
from repro.core.distributed import (ring_matrix_profile, drag_discords,
                                    distributed_discords)
from repro.core.serial.brute import exact_nnd_profile
from repro.core import find_discords

rng = np.random.default_rng(0)
x = np.sin(0.08 * np.arange(2500)) + 0.15 * rng.normal(size=2500)
x[1200:1260] += 1.2 * np.sin(np.linspace(0, np.pi, 60))
s = 80

d, arg = ring_matrix_profile(x, s)
prof = exact_nnd_profile(x, s)
ok_mp = bool(np.allclose(d, prof, atol=1e-3))

r_ring = distributed_discords(x, s, 3)
r_drag = drag_discords(x, s, 3)
r_ref = find_discords(x, s, 3, method="brute")
# pruning power is only meaningful when r discriminates: k=1 puts r
# just under the top discord's nnd
r_drag1 = drag_discords(x, s, 1)
# the pluggable tile backend must also work inside the shard body
# (pallas runs gridded, interpret mode on CPU)
r_pl = distributed_discords(x[:900], s, 1, backend="pallas")
r_pl_ref = find_discords(x[:900], s, 1, method="brute")
print(json.dumps({
    "ok_mp": ok_mp,
    "ring_pos": r_ring.positions, "drag_pos": r_drag.positions,
    "ref_pos": r_ref.positions,
    "ring_pallas_pos": r_pl.positions,
    "ring_pallas_ref": r_pl_ref.positions,
    "drag_survivors_k1": r_drag1.extra["survivors"],
    "n": int(prof.shape[0]),
}))
"""


@pytest.fixture(scope="module")
def result():
    p = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_ring_matrix_profile_exact(result):
    assert result["ok_mp"]


def test_ring_discords_match_brute(result):
    assert result["ring_pos"] == result["ref_pos"]


def test_drag_discords_match_brute(result):
    assert result["drag_pos"] == result["ref_pos"]


def test_ring_pallas_backend_match_brute(result):
    assert result["ring_pallas_pos"] == result["ring_pallas_ref"]


def test_drag_pruning_effective(result):
    """Phase 1 must kill the overwhelming majority of candidates when
    the range r sits just under the top discord's nnd (k=1)."""
    assert result["drag_survivors_k1"] < 0.2 * result["n"]

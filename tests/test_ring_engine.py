"""Ring plan family: mesh-sharded searches through the session layer.

Runs on 4 forced host-platform devices in a subprocess (device count
must be set before jax init) and pins the PR-3 contract:

  1. PARITY — ring-plan results (nnd profile, neighbors, top-k) match
     the single-device engine exactly, for block-aligned and unaligned
     shard geometries.
  2. COMPILE-ONCE, MESH-WIDE — the second same-bucket sharded search
     adds zero new jit traces (``stats.traces``).
  3. STREAMING — a sharded stream (ring fill + per-shard tail sweeps
     with a global min-fold) matches the exact profile, sweeping fewer
     lanes per append than a full resweep.
  4. TWO-LEVEL BATCHED — series-parallel layout below the
     per-device threshold, ring-per-series above it, both matching
     per-series single-device searches.
  5. CPS — all four planes (serial, hst_jax, engine, ring) report the
     shared work definition of docs/cps.md:
     ``cps == calls / (n * k)``, with ``calls == tile_lanes`` on the
     tiled planes and ``tile_lanes == 0`` on the serial counted plane.
"""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.pop("REPRO_RING_SERIES_THRESHOLD", None)
import json
import numpy as np
import jax
from repro.core import DiscordEngine, SearchSpec
from repro.core.serial.brute import exact_nnd_profile

rng = np.random.default_rng(0)
x = np.sin(0.08 * np.arange(2500)) + 0.15 * rng.normal(size=2500)
x[1200:1260] += 1.2 * np.sin(np.linspace(0, np.pi, 60))
s = 80
out = {"ndev": len(jax.devices())}

# -- parity, aligned and unaligned shard geometry ----------------------
# block=256: bucket 4096 -> 16 blocks over 4 devices (aligned shards);
# block=64:  31 blocks over 4 devices (needs device-count padding).
for tag, block in (("aligned", 256), ("unaligned", 64)):
    ring = DiscordEngine(SearchSpec(s=s, k=3, method="ring",
                                    block=block, backend="xla"))
    local = DiscordEngine(SearchSpec(s=s, k=3, method="matrix_profile",
                                     block=block, backend="xla"))
    prof_r, ngh_r, *_ = ring._ring_profile(x, s)
    xp = np.zeros(4096, np.float32)
    xp[:x.size] = x
    n = x.size - s + 1
    d2_l, ngh_l = local._profile_plan(s, 4096)(xp, np.int32(n))
    prof_l = np.sqrt(np.asarray(d2_l, np.float64)[:n])
    out[f"prof_close_{tag}"] = bool(np.allclose(prof_r, prof_l,
                                                rtol=1e-4, atol=1e-4))
    out[f"ngh_equal_{tag}"] = bool(
        np.array_equal(ngh_r, np.asarray(ngh_l, np.int64)[:n]))
    rr, rl = ring.search(x), local.search(x)
    out[f"pos_equal_{tag}"] = rr.positions == rl.positions
    out[f"nnd_close_{tag}"] = bool(np.allclose(rr.nnds, rl.nnds,
                                               rtol=1e-5))

# -- zero retrace on the second same-bucket sharded search -------------
eng = DiscordEngine(SearchSpec(s=s, k=3, method="ring", backend="xla"))
eng.search(x)
t1 = eng.stats.traces
eng.search(x[:2400])                      # same 4096 bucket, new length
out["traces_first"] = t1
out["traces_second"] = eng.stats.traces
out["plans"] = eng.stats.plans

# -- sharded stream: ring fill + per-shard tail sweep + global fold ----
st = eng.open_stream(history=x[:2000])
fill_lanes = st.tile_lanes
for lo in range(2000, 2500, 137):
    st.append(x[lo:lo + 137])
ref = exact_nnd_profile(np.asarray(x, np.float64), s)
out["stream_close"] = bool(np.allclose(st.profile(), ref, atol=3e-3))
out["stream_appends"] = st.appends
out["append_lanes_lt_fill"] = bool(st.tile_lanes - fill_lanes
                                   < fill_lanes)
full = eng.search(x)
got = st.discords()
out["stream_pos_equal"] = got.positions == full.positions

# -- two-level batched layout ------------------------------------------
stack = np.stack([x[:1000], x[1000:2000], x[500:1500]])
local1 = DiscordEngine(SearchSpec(s=s, k=3, method="matrix_profile",
                                  backend="xla"))
refs = [local1.search(row) for row in stack]
rs = eng.search_batched(stack)            # short series: series-parallel
out["batched_layout_short"] = rs[0].extra["layout"]
out["batched_pos_equal_short"] = all(
    r.positions == f.positions for r, f in zip(rs, refs))
os.environ["REPRO_RING_SERIES_THRESHOLD"] = "100"
rs2 = eng.search_batched(stack)           # now "long": ring per series
out["batched_layout_long"] = rs2[0].extra["layout"]
out["batched_pos_equal_long"] = all(
    r.positions == f.positions for r, f in zip(rs2, refs))

# -- shared cps definition across the four planes ----------------------
planes = {
    "serial": DiscordEngine(SearchSpec(s=s, k=3,
                                       method="hst")).search(x),
    "hst_jax": DiscordEngine(SearchSpec(s=s, k=3, method="hst_jax",
                                        backend="xla")).search(x),
    "engine": local1.search(x),
    "ring": eng.search(x),
}
cps = {}
for name, r in planes.items():
    cps[name] = {
        "cps_matches": abs(r.cps - r.calls / (r.n * r.k)) < 1e-9,
        "tile_lanes": int(r.tile_lanes),
        "calls": int(r.calls),
        "k": r.k,
    }
out["cps"] = cps
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    p = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_runs_on_four_devices(result):
    assert result["ndev"] == 4


@pytest.mark.parametrize("tag", ["aligned", "unaligned"])
def test_ring_profile_matches_single_device(result, tag):
    assert result[f"prof_close_{tag}"]
    assert result[f"ngh_equal_{tag}"]


@pytest.mark.parametrize("tag", ["aligned", "unaligned"])
def test_ring_topk_matches_single_device(result, tag):
    assert result[f"pos_equal_{tag}"]
    assert result[f"nnd_close_{tag}"]


def test_second_sharded_search_adds_zero_traces(result):
    assert result["traces_first"] == result["traces_second"] == 1
    assert result["plans"] == 1


def test_sharded_stream_parity_and_tail_only_lanes(result):
    assert result["stream_close"]
    assert result["stream_pos_equal"]
    assert result["stream_appends"] == 5
    assert result["append_lanes_lt_fill"]


def test_batched_two_level_layout(result):
    assert result["batched_layout_short"] == "series-parallel"
    assert result["batched_layout_long"] == "ring-per-series"
    assert result["batched_pos_equal_short"]
    assert result["batched_pos_equal_long"]


def test_cps_shared_definition_across_planes(result):
    cps = result["cps"]
    for name, row in cps.items():
        assert row["cps_matches"], name
        assert row["k"] == 3, name
    # tiled planes: calls IS the swept lane count
    for name in ("hst_jax", "engine", "ring"):
        assert cps[name]["tile_lanes"] == cps[name]["calls"] > 0, name
    # serial counted plane has no tile plane
    assert cps["serial"]["tile_lanes"] == 0
    assert cps["serial"]["calls"] > 0

"""Hypothesis property suite for the serve plane's parity contract.

Drives the same randomized-fleet case runner as
``test_serve_fleet.py`` (mixed specs, ladders, znorm modes, append
sizes and order, tight cache budgets forcing mid-flight evictions),
but lets hypothesis explore and shrink the seed space.  Skipped
cleanly when hypothesis is not installed — the seeded parametrized
variant in test_serve_fleet.py still covers every backend there.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings      # noqa: E402
from hypothesis import strategies as st                  # noqa: E402

from test_serve_fleet import BACKENDS, run_fleet_case    # noqa: E402


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       backend=st.sampled_from(BACKENDS))
def test_fleet_parity_property(seed, backend):
    """Micro-batched coalesced appends are bit-identical to
    per-tenant sequential appends for arbitrary fleets."""
    run_fleet_case(seed, backend)
